"""MoE block wired to the NCCL-EP core (the paper's §VI "FusedMoE layer").

The block enters a `shard_map` island over the full mesh; inside, tokens are
laid out one-shard-per-EP-rank and the unified ep_dispatch/ep_combine
primitives run over `MoESpec.ep_axis`. Expert weights are block-distributed
over the same axis (rank r hosts experts [r*L, (r+1)*L)), with the expert FFN
optionally tensor-parallel over the model axis when it is not part of the EP
axis (Megatron "ETP": the a2a is then replicated per TP rank — per-chip wire
bytes unchanged).

Deployment presets (mirrors the paper's vLLM/Megatron integrations):
  * training / prefill, many experts (DeepSeek-V3): ep_axis=("data","model"),
    HT mode, optionally hierarchical (outer=data, inner=model);
  * training, few experts (DBRX, E=16): ep_axis=("data",), expert-TP on model;
  * decode (both): ep_axis=("data",), LL mode, B/rank <= 128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,
                        ep_dispatch, ep_combine, ep_complete)
from repro.core.placement import expand_expert_params, collapse_expert_params
from repro.core.routing import RouterConfig, route
from repro.kernels import ops as K
from repro.models.config import ArchConfig
from repro.models.layers import ffn_spec, ffn_apply
from repro.parallel.sharding import ParamSpec

def _num_weight_rows(m) -> int:
    """Leading dim of the expert-stacked weights under the param-layout
    mode: physical slot count in adopt-once mode (== E when placement is
    None or identity), logical E otherwise."""
    if m.params_physical and m.placement is not None:
        return m.placement.num_slots
    return m.num_experts


def moe_spec(cfg: ArchConfig, dtype=None):
    """Param specs. The expert-stacked weights (w_gate/w_up/w_down — the
    ``checkpoint.EXPERT_PARAM_KEYS``) follow the layout mode: logical
    [E, ...] by default, physical [N*S, ...] under ``params_physical``
    (router and sel_bias always stay logical — routing is a logical-expert
    concept). NOTE: physical specs describe shapes/sharding only; random
    init must go through the LOGICAL spec + one adoption
    (checkpoint.adopt_expert_params) so replicas hold identical weights."""
    m, d = cfg.moe, cfg.d_model
    dtype = dtype or cfg.dtype
    f = m.d_ff_expert
    P_rows = _num_weight_rows(m)
    sp = dict(
        router=ParamSpec((d, m.num_experts), jnp.float32, ("embed", None)),
        w_gate=ParamSpec((P_rows, d, f), dtype, ("expert", "embed", "expert_ffn")),
        w_up=ParamSpec((P_rows, d, f), dtype, ("expert", "embed", "expert_ffn")),
        w_down=ParamSpec((P_rows, f, d), dtype, ("expert", "expert_ffn", "embed")),
    )
    if m.use_selection_bias:
        sp["sel_bias"] = ParamSpec((m.num_experts,), jnp.float32, (None,), init="zeros")
    if m.shared_experts:
        sp["shared"] = ffn_spec(d, m.shared_experts * f, dtype, cfg.act)
    return sp


def _token_specs(mesh, ep_axis):
    """(batch_axes, seq_axes) for the [B, S, D] token layout inside the MoE
    shard_map.

    The EP rank partition of tokens is carried by the batch dim for every EP
    axis EXCEPT "model", which splits the sequence dim (Megatron
    sequence-parallel style). Keeping B on ("pod","data") in all cases means
    the shard_map boundary only ever *slices S over model* relative to the
    attention layout — a local operation. (The earlier layout moved B off
    "data" onto nothing and S onto ("data","model"): GSPMD cannot reshard
    that transition incrementally and fell back to full replication of
    [B,S,D] per MoE layer — measured 33.5 TiB/dev temps on the deepseek-v3
    prefill cell. See docs/EXPERIMENTS.md §Perf iteration D1.)"""
    present = set(mesh.shape.keys())
    ep = tuple(a for a in ep_axis if a in present)
    b_axes = tuple(a for a in ("pod", "data") if a in present)
    s_axes = tuple(a for a in ep if a == "model")
    return b_axes, s_axes, ep


def _router_cfg(m) -> RouterConfig:
    return RouterConfig(
        num_experts=m.num_experts, top_k=m.top_k, gating=m.gating,
        n_groups=m.n_groups, topk_groups=m.topk_groups,
        use_selection_bias=m.use_selection_bias,
        routed_scaling_factor=m.routed_scaling, norm_topk_prob=m.norm_topk,
        aux_loss_weight=m.aux_loss_weight, z_loss_weight=1e-4,
    )


def _resolve_chunks(nc: int, tokens_per_rank: int) -> int:
    """Chunk count for this cell's per-rank token count. A configured chunk
    count that does not tile the tokens cannot run (group creation would
    raise) — fall back to monolithic, but LOUDLY: a preset that asks for the
    chunked pipeline should never lose it without a trace."""
    if tokens_per_rank % nc == 0:
        return nc
    import warnings
    warnings.warn(
        f"ht_num_chunks={nc} does not divide tokens_per_rank="
        f"{tokens_per_rank} for this cell; running the monolithic (nc=1) "
        "hierarchical path instead", stacklevel=2)
    return 1


def _expert_ffn(group, y3d, counts, w1, w3, w2, act, tp_axis):
    """Grouped SwiGLU over [L, A, D]; counts-masked; optional TP psum."""
    if group.mode == "baseline":
        counts = jnp.full_like(counts, y3d.shape[1])   # padded rows computed
    g = K.grouped_gemm(y3d, w1, counts)
    u = K.grouped_gemm(y3d, w3, counts)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(y3d.dtype)
    out = K.grouped_gemm(h, w2, counts)
    if tp_axis is not None:
        out = jax.lax.psum(out, tp_axis)               # expert-TP partials
    return out


def moe_block(p, x, cfg: ArchConfig, mesh, *, with_heat: bool = False):
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar).

    With ``with_heat=True`` additionally returns the per-logical-expert
    routed-token histogram [E] (replicated), the signal the EPLB rebalancer
    consumes (runtime/server.py folds it into the decode state)."""
    m = cfg.moe

    def _fallback():
        y, heat = _moe_dense_fallback(p, x, cfg, with_heat=True)
        return (y, jnp.float32(0), heat) if with_heat else (y, jnp.float32(0))

    if mesh is None or mesh.empty:
        return _fallback()

    b_axes, s_axes, ep = _token_specs(mesh, m.ep_axis)
    ep_sizes = [mesh.shape[a] for a in ep]
    N = math.prod(ep_sizes) if ep else 1
    if m.placement is not None and N > 1 and m.placement.num_ranks != N:
        raise ValueError(
            f"MoESpec.placement spans {m.placement.num_ranks} ranks but the "
            f"mesh's EP extent is {N}")
    phys = m.placement.num_slots if m.placement is not None else m.num_experts
    if N <= 1 or phys % N != 0:
        return _fallback()
    B, S, D = x.shape
    # tokens per EP rank (static)
    b_div = math.prod(mesh.shape[a] for a in b_axes) if b_axes else 1
    s_div = math.prod(mesh.shape[a] for a in s_axes) if s_axes else 1
    T = (B // b_div) * (S // s_div)
    tp_axis = "model" if ("model" in mesh.shape and "model" not in ep) else None

    gcfg = EpGroupConfig(
        num_experts=m.num_experts, max_tokens_per_rank=T, hidden=D,
        top_k=m.top_k, mode=m.ep_mode, ll_layout=m.ll_layout,
        capacity_factor=m.capacity_factor,
        expert_capacity_factor=m.expert_capacity_factor,
        payload_dtype=cfg.dtype, quantize_dispatch=m.quantize_dispatch,
        ep_axis=ep, ht_hierarchical=m.ht_hierarchical,
        ht_num_chunks=_resolve_chunks(m.ht_num_chunks, T),
        placement=m.placement,
    )
    group = ep_create_group(gcfg, ep_size=N, inner_size=ep_sizes[-1])

    tok_spec = P(tuple(b_axes) or None, tuple(s_axes) or None, None)
    ew_spec = P(tuple(ep), None, "model" if tp_axis else None)
    ew_spec_t = P(tuple(ep), "model" if tp_axis else None, None)
    bias = p.get("sel_bias")

    def inner(xs, router_w, w1, w3, w2, sel_bias):
        Bl, Sl, Dl = xs.shape
        xt = xs.reshape(Bl * Sl, Dl)
        logits = xt.astype(jnp.float32) @ router_w
        r = route(logits, _router_cfg(m), sel_bias)
        handle = ep_create_handle(group, r.topk_idx, r.topk_weights)
        # The staged surface is every backend's primitive (eager is defined
        # as send ∘ complete, core/backend.py), so the model layer uses it
        # unconditionally — same trace as the eager calls, no per-mode
        # branching, and the EpPending seam sits where a micro-batching
        # scheduler (runtime/prefill.py's schedule) would interleave expert
        # compute. For HT presets the send half is the whole (chunk-
        # pipelined, when hierarchical) collective stream.
        pend = ep_dispatch(group, handle, xt, send_only=True)
        y3d, counts = ep_complete(group, handle, pend)
        y3d = _expert_ffn(group, y3d, counts, w1, w3, w2, cfg.act, tp_axis)
        pc = ep_combine(group, handle, y3d, send_only=True)
        out = ep_complete(group, handle, pc).astype(xs.dtype)
        # aux losses averaged over the token-carrying axes (the value is
        # invariant along a pure-TP model axis — pmean there is ill-typed)
        aux = r.aux_loss + r.z_loss
        vary = tuple(dict.fromkeys(b_axes + s_axes))
        if vary:
            aux = jax.lax.pmean(aux, vary)
        if not with_heat:
            return out.reshape(Bl, Sl, Dl), aux
        # per-logical-expert routed-token heat (the EPLB rebalance signal);
        # psum over the token-carrying axes makes it the global histogram,
        # and it is invariant along a pure-TP model axis like aux
        heat = jnp.zeros((m.num_experts,), jnp.float32).at[
            r.topk_idx.reshape(-1)].add(1.0, mode="drop")
        if vary:
            heat = jax.lax.psum(heat, vary)
        return out.reshape(Bl, Sl, Dl), aux, heat

    sel = bias if bias is not None else jnp.zeros((m.num_experts,), jnp.float32)
    out_specs = (tok_spec, P(), P(None)) if with_heat else (tok_spec, P())
    fn = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(tok_spec, P(None, None), ew_spec, ew_spec, ew_spec_t, P(None)),
        out_specs=out_specs,
    )
    w1, w3, w2 = p["w_gate"], p["w_up"], p["w_down"]
    if m.placement is not None:
        if m.params_physical:
            # adopt-once mode (serving fast path): weights arrive ALREADY in
            # physical [N*S, ...] slot order — rebound host-side at the last
            # placement-adoption boundary (checkpoint.adopt_expert_params) —
            # so the per-step cross-rank gather is skipped entirely and the
            # placed steady state matches placement=None per-step cost.
            if w1.shape[0] != phys:
                raise ValueError(
                    f"params_physical=True: expert weights have "
                    f"{w1.shape[0]} rows but the placement defines {phys} "
                    "physical slots — rebind at adoption via "
                    "checkpoint.adopt_expert_params / rebind_expert_leaves")
        else:
            # logical mode (training default): params stay stored logical
            # [E, ...]; each physical slot gathers its expert's weights
            # (replicas duplicate) before the shard_map splits them over the
            # EP axes — resolved at the same altitude as the plan's slot
            # maps, never inside phase bodies. The gather runs per forward
            # step (cross-rank for moved experts), which keeps checkpoints
            # placement-independent across mid-epoch swaps.
            w1, w3, w2 = (expand_expert_params(w, m.placement)
                          for w in (w1, w3, w2))
    res = fn(x, p["router"], w1, w3, w2, sel)
    y, aux = res[0], res[1]
    if m.shared_experts:
        y = y + ffn_apply(p["shared"], x, cfg.act)
    return (y, aux, res[2]) if with_heat else (y, aux)


def _moe_dense_fallback(p, x, cfg: ArchConfig, *, with_heat: bool = False):
    """Reference MoE for meshless smoke tests: dense routing, no EP comms.
    Semantics identical to the EP path (same router, same expert math)."""
    m = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    r = route(xt.astype(jnp.float32) @ p["router"], _router_cfg(m),
              p.get("sel_bias"))
    w1, w3, w2 = p["w_gate"], p["w_up"], p["w_down"]
    if m.params_physical and m.placement is not None:
        # the dense reference routes by logical expert: collapse physical
        # slot-ordered weights to logical order (primary replica)
        w1, w3, w2 = (collapse_expert_params(w, m.placement)
                      for w in (w1, w3, w2))
    h_g = jnp.einsum("td,edf->tef", xt, w1)
    h_u = jnp.einsum("td,edf->tef", xt, w3)
    h = (jax.nn.silu(h_g.astype(jnp.float32)) * h_u.astype(jnp.float32)).astype(x.dtype)
    y_all = jnp.einsum("tef,efd->ted", h, w2)            # [T, E, D]
    oh = jax.nn.one_hot(r.topk_idx, m.num_experts, dtype=jnp.float32)
    gate = jnp.einsum("tk,tke->te", r.topk_weights, oh)  # [T, E]
    y = jnp.einsum("ted,te->td", y_all.astype(jnp.float32), gate).astype(x.dtype)
    y = y.reshape(B, S, D)
    if m.shared_experts:
        y = y + ffn_apply(p["shared"], x, cfg.act)
    if with_heat:
        heat = jnp.zeros((m.num_experts,), jnp.float32).at[
            r.topk_idx.reshape(-1)].add(1.0, mode="drop")
        return y, heat
    return y
