"""Quickstart: the unified EP API in ~40 lines.

Creates an 8-rank EP group, routes tokens with a real top-k router, runs
dispatch -> per-expert transform -> combine, and shows the mode switch
(LL <-> HT <-> baseline) changing NOTHING at the call sites — the paper's
headline property.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,
                        ep_dispatch, ep_combine)
from repro.core.routing import RouterConfig, route

E, K, T, H, N = 32, 4, 16, 64, 8
mesh = jax.make_mesh((N,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(N, T, H), jnp.float32)
router_w = jnp.asarray(rng.randn(H, E) * 0.1, jnp.float32)

for mode in ("ll", "ht", "baseline"):
    group = ep_create_group(
        EpGroupConfig(num_experts=E, max_tokens_per_rank=T, hidden=H,
                      top_k=K, mode=mode, payload_dtype=jnp.float32),
        ep_size=N)

    def step(x):
        xt = x[0]
        r = route(xt @ router_w, RouterConfig(num_experts=E, top_k=K))
        handle = ep_create_handle(group, r.topk_idx, r.topk_weights)
        expert_in, counts = ep_dispatch(group, handle, xt)     # [L, A, H]
        expert_out = jnp.tanh(expert_in)                        # "expert FFN"
        y = ep_combine(group, handle, expert_out)               # [T, H]
        return y[None], counts[None]

    f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=P("data"),
                              out_specs=(P("data"), P("data"))))
    y, counts = f(x)
    print(f"mode={mode:9s} out={y.shape} tokens/expert: "
          f"min={int(counts.min())} max={int(counts.max())} "
          f"total={int(counts.sum())} (== N*T*K = {N*T*K})")
print("same call sites, three algorithms — ep mode is a group-creation knob.")
