"""Staged execution (send_only + ep_complete): the paper's double-buffered
LL overlap (§III-B, §IV). Two micro-batches are pipelined so the dispatch
collective of batch i+1 is exposed to XLA concurrently with the expert GEMM
of batch i — the dataflow the paper realizes with double buffers and staged
sends.

  PYTHONPATH=src python examples/staged_overlap.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import (EpGroupConfig, ep_create_group, ep_create_handle,
                        ep_dispatch, ep_combine, ep_complete)
from repro.core.routing import RouterConfig, route

E, K, T, H, N = 16, 4, 32, 128, 8
mesh = jax.make_mesh((N,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
group = ep_create_group(EpGroupConfig(
    num_experts=E, max_tokens_per_rank=T, hidden=H, top_k=K, mode="ll",
    payload_dtype=jnp.float32), ep_size=N)
rng = np.random.RandomState(0)
router_w = jnp.asarray(rng.randn(H, E) * 0.1, jnp.float32)


def expert_fn(y3d):
    return jnp.tanh(y3d) * 1.5


def pipelined(xs):            # xs: [2, T, H] two micro-batches per rank
    outs = []
    handles, pendings = [], []
    for i in range(2):        # stage 1: launch both dispatches
        r = route(xs[i] @ router_w, RouterConfig(num_experts=E, top_k=K))
        h = ep_create_handle(group, r.topk_idx, r.topk_weights)
        p = ep_dispatch(group, h, xs[i], send_only=True)
        handles.append(h)
        pendings.append(p)
    for i in range(2):        # stage 2: complete + compute + combine
        y3d, counts = ep_complete(group, handles[i], pendings[i])
        pc = ep_combine(group, handles[i], expert_fn(y3d), send_only=True)
        outs.append(ep_complete(group, handles[i], pc))
    return jnp.stack(outs)


def sequential(xs):
    outs = []
    for i in range(2):
        r = route(xs[i] @ router_w, RouterConfig(num_experts=E, top_k=K))
        h = ep_create_handle(group, r.topk_idx, r.topk_weights)
        y3d, counts = ep_dispatch(group, h, xs[i])
        outs.append(ep_combine(group, h, expert_fn(y3d)))
    return jnp.stack(outs)


if __name__ == "__main__":
    x = jnp.asarray(rng.randn(N, 2, T, H), jnp.float32)
    sm = lambda f: jax.jit(jax.shard_map(
        lambda a: f(a[0])[None], mesh=mesh, in_specs=P("data"),
        out_specs=P("data")))
    y_pipe = sm(pipelined)(x)
    y_seq = sm(sequential)(x)
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                               rtol=1e-5, atol=1e-5)
    print(f"staged == fused: True; out {y_pipe.shape}")
    print("HLO of the staged version exposes both a2a ops before the first "
          "expert GEMM -> XLA's scheduler overlaps comm with compute.")
