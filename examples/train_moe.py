"""Train a MoE LM through the HT expert-parallel path with checkpointing and
preemption-safe restart — the paper's Megatron-LM scenario (§VI-B) in
miniature. Configurable up to a ~100M-parameter model.

  PYTHONPATH=src python examples/train_moe.py                 # quick (~1 min)
  PYTHONPATH=src python examples/train_moe.py --big --steps 300   # ~100M params
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses

import jax

from repro.configs import get_smoke
from repro.models.config import ArchConfig, AttnSpec, MoESpec
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def big_config():
    """~100M-param MoE decoder (8 experts top-2)."""
    return ArchConfig(
        name="moe-100m", family="lm", num_layers=8, d_model=512,
        d_ff=2048, vocab=32000,
        attn=AttnSpec(n_heads=8, n_kv=4, head_dim=64),
        moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=1024,
                    ep_mode="ht", ep_axis=("data",), capacity_factor=1.5,
                    expert_capacity_factor=1.5),
        remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt", default="/tmp/repro_moe_ckpt")
    args = ap.parse_args()

    cfg = big_config() if args.big else get_smoke("dbrx-132b")
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    t = Trainer(cfg, TrainerConfig(
        steps=args.steps, global_batch=8, seq_len=128 if args.big else 64,
        ckpt_dir=args.ckpt, ckpt_every=20, log_every=5),
        mesh=mesh,
        opt_cfg=AdamWConfig(lr=1e-3, total_steps=args.steps,
                            warmup_steps=max(args.steps // 20, 1)))
    t.run()
    print("done. re-run the same command to watch it RESUME from the "
          f"latest checkpoint in {args.ckpt} (preemption/restart path).")


if __name__ == "__main__":
    main()
