"""End-to-end driver: serve a small MoE model with batched requests through
the LL expert-parallel path on an 8-rank mesh — the paper's vLLM scenario
(§VI-C) in miniature, including the staged double-buffered pipeline variant.

  PYTHONPATH=src python examples/serve_decode.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.runtime.server import DecodeServer

BATCH, PROMPT, GEN = 16, 8, 48


def run(mode: str, layout: str = "nccl_ep"):
    cfg = get_smoke("dbrx-132b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, ep_mode=mode, ll_layout=layout))
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    srv = DecodeServer(cfg, batch=BATCH, max_len=PROMPT + GEN + 8, mesh=mesh)
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (BATCH, PROMPT)), jnp.int32)
    m = srv.serve(prompts, gen_steps=GEN)
    print(f"  backend={mode}/{layout:8s} out_tok/s={m.output_tok_s:8.1f} "
          f"ttft={m.ttft_s*1e3:6.1f}ms itl={m.itl_mean_s*1e3:5.2f}ms "
          f"p99={m.itl_p99_s*1e3:5.2f}ms")
    return m


if __name__ == "__main__":
    print(f"serving {BATCH} requests, prompt={PROMPT}, gen={GEN} "
          f"(MoE 8e top-2, 8-rank EP):")
    run("ll", "nccl_ep")     # the paper's optimized LL layout
    run("ll", "deepep")      # the DeepEP layout it improves on
    run("baseline")          # Megatron-style AllToAll dispatcher
