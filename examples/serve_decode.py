"""End-to-end driver: serve a small MoE model with batched requests through
the LL expert-parallel path on an 8-rank mesh — the paper's vLLM scenario
(§VI-C) in miniature, including the staged double-buffered pipeline variant
and the EPLB adopt-once serving mode (``MoESpec.params_physical``: expert
weights live in the active placement's physical slot order and are rebound
host-side once per rebalance boundary instead of gathered every step).

  PYTHONPATH=src python examples/serve_decode.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.runtime.server import DecodeServer

BATCH, PROMPT, GEN = 16, 8, 48


def run(mode: str, layout: str = "nccl_ep", adopt_once: bool = False,
        trace: bool = False):
    cfg = get_smoke("dbrx-132b")
    moe = dataclasses.replace(cfg.moe, ep_mode=mode, ll_layout=layout)
    kw = {}
    if adopt_once:
        # EPLB adopt-once serving: heat-driven rebalancing every 16 steps
        # with 8 redundant replica slots; params_physical binds the expert
        # weights to each adopted placement's slot order exactly once at the
        # boundary (checkpoint.adopt_expert_params) — no per-step expansion.
        moe = dataclasses.replace(moe, track_expert_heat=True,
                                  params_physical=True)
        kw = dict(rebalance_every=16, num_redundant_experts=8)
    if trace:
        # telemetry (docs/DESIGN.md §11): spans at the existing host-side
        # step boundaries, exported as Chrome-trace JSON — open the printed
        # file in Perfetto (ui.perfetto.dev) or chrome://tracing
        from repro.runtime.telemetry import TimeSeries, Tracer
        kw.update(tracer=Tracer(), series=TimeSeries())
    cfg = dataclasses.replace(cfg, moe=moe)
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    srv = DecodeServer(cfg, batch=BATCH, max_len=PROMPT + GEN + 8, mesh=mesh,
                       **kw)
    prompts = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab, (BATCH, PROMPT)), jnp.int32)
    m = srv.serve(prompts, gen_steps=GEN)
    tag = f"{mode}/{layout}" + ("/adopt-once" if adopt_once else "")
    extra = (f" swaps={len(srv.placements)}" if adopt_once else "")
    print(f"  backend={tag:22s} out_tok/s={m.output_tok_s:8.1f} "
          f"ttft={m.ttft_s*1e3:6.1f}ms itl={m.itl_mean_s*1e3:5.2f}ms "
          f"p99={m.itl_p99_s*1e3:5.2f}ms{extra}")
    if trace:
        import pathlib
        out = pathlib.Path("results") / "serve_decode_trace.json"
        srv.tracer.write_chrome_trace(out)
        spans = sum(r["count"] for r in m.timeline.values())
        print(f"  wrote {out} ({spans} events; open in ui.perfetto.dev)")
    return m


if __name__ == "__main__":
    print(f"serving {BATCH} requests, prompt={PROMPT}, gen={GEN} "
          f"(MoE 8e top-2, 8-rank EP):")
    run("ll", "nccl_ep")     # the paper's optimized LL layout
    run("ll", "deepep")      # the DeepEP layout it improves on
    run("baseline")          # Megatron-style AllToAll dispatcher
    # EPLB adopt-once rebalancing, telemetry on -> Perfetto-readable trace
    run("ll", "nccl_ep", adopt_once=True, trace=True)
